"""1F1B schedule invariants (paper §3.3) — property-based."""
import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from repro.core.schedule import Schedule1F1B, paper_noam

sizes = st.tuples(st.integers(1, 8), st.integers(1, 24))


@given(sizes)
def test_every_microbatch_scheduled_exactly_once(sr):
    s, r = sr
    sched = Schedule1F1B(s, r)
    fwd, bwd = sched.tables()
    for stage in range(s):
        f = [m for m in fwd[:, stage] if m >= 0]
        b = [m for m in bwd[:, stage] if m >= 0]
        assert sorted(f) == list(range(r))
        assert sorted(b) == list(range(r))


@given(sizes)
def test_forward_before_backward_and_downstream(sr):
    s, r = sr
    sched = Schedule1F1B(s, r)
    fwd, bwd = sched.tables()
    for stage in range(s):
        for m in range(r):
            tf = int(np.where(fwd[:, stage] == m)[0][0])
            tb = int(np.where(bwd[:, stage] == m)[0][0])
            # B(m) at this stage comes at/after the output stage's F(m)
            tf_out = int(np.where(fwd[:, s - 1] == m)[0][0])
            assert tb >= tf_out >= tf
            # activations flow downstream one stage per tick
            if stage + 1 < s:
                tf_next = int(np.where(fwd[:, stage + 1] == m)[0][0])
                assert tf_next == tf + 1
            if stage > 0:
                tb_prev = int(np.where(bwd[:, stage - 1] == m)[0][0])
                assert tb_prev == tb + 1


@given(sizes)
def test_steady_state_no_idle(sr):
    """Paper: in steady state no GPU is idle — both slots busy."""
    s, r = sr
    sched = Schedule1F1B(s, r)
    fwd, bwd = sched.tables()
    rng = sched.steady_state_ticks()
    if rng is None:
        return
    lo, hi = rng
    for tick in range(lo, hi + 1):
        assert (fwd[tick] >= 0).all() and (bwd[tick] >= 0).all()


@given(sizes)
def test_max_in_flight_bound(sr):
    """Microbatches alive between F and B at stage s: ≤ 2(S−1−s)+1 —
    the weight-stash ring size (paper: NOAM versions at the input
    stage)."""
    s, r = sr
    sched = Schedule1F1B(s, r)
    fwd, bwd = sched.tables()
    for stage in range(s):
        live = set()
        peak = 0
        for tick in range(sched.n_ticks):
            if fwd[tick, stage] >= 0:
                live.add(int(fwd[tick, stage]))
            peak = max(peak, len(live))
            if bwd[tick, stage] >= 0:
                live.discard(int(bwd[tick, stage]))
        assert peak <= sched.max_in_flight(stage)
        assert sched.max_in_flight(stage) <= sched.stash_slots


@given(sizes)
def test_stash_ring_slots_never_clobbered(sr):
    """Ring slot m % V written at F(m) must survive until B(m)."""
    s, r = sr
    sched = Schedule1F1B(s, r)
    v = sched.stash_slots
    fwd, bwd = sched.tables()
    for stage in range(s):
        writer = {}
        for tick in range(sched.n_ticks):
            m = int(fwd[tick, stage])
            if m >= 0:
                slot = m % v
                assert slot not in writer, "slot reused while still live"
                writer[slot] = m
            b = int(bwd[tick, stage])
            if b >= 0:
                assert writer.pop(b % v) == b


@given(sizes)
def test_bubble_fraction(sr):
    s, r = sr
    sched = Schedule1F1B(s, r)
    fwd, bwd = sched.tables()
    busy = int((fwd >= 0).sum() + (bwd >= 0).sum())
    total = 2 * sched.n_ticks * s
    assert abs(sched.bubble_fraction - (1 - busy / total)) < 1e-12


def test_noam():
    assert paper_noam(8, 7) == 2       # VGG16 "7-1" config
    assert paper_noam(8, 2) == 4
    assert paper_noam(4, 4) == 1       # pure data parallel
    assert paper_noam(16, 9) == 2      # "9-5-1-1"
