"""Schedule-table invariants (paper §3.3) for every registered schedule.

Each schedule's tables must prove, per (S, R, v) grid point:
  * every (microbatch, chunk) is forwarded and backwarded exactly once
    per owning stage, and B(m) never precedes the last-chunk F(m);
  * activations/gradients are consumed exactly one tick after they are
    produced (the executor's single-buffer dataflow contract);
  * residual-ring liveness: the slot written at F survives to its B
    read within the declared ``resid_slots`` budget;
  * stash-ring liveness for 1F1B (slot m % V never clobbered while a
    microbatch is in flight);
  * ``bubble_fraction`` matches the slot-level simulator
    (benchmarks/simulator.simulate_schedule), and interleaving shrinks
    it for v >= 2 whenever S >= 3 (at S = 2 startup+drain are already
    minimal in the double-tick model and the fraction ties).

Property-based variants run when hypothesis is installed (it is in
requirements-dev.txt); the grid tests carry the whole load otherwise.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.simulator import simulate_schedule  # noqa: E402
from repro.core.schedule import (B_CHUNK, B_MB, B_VERSION, F_CHUNK, F_MB,  # noqa: E402
                                 F_STASH_WRITE, SCHEDULES, Schedule1F1B,
                                 ScheduleGPipe, ScheduleInterleaved1F1B,
                                 ScheduleInterleavedAsync1F1B,
                                 make_schedule, paper_noam,
                                 register_schedule)
from repro.parallel.mesh import ParallelismPlan  # noqa: E402

GRID_PLAIN = [(1, 1), (1, 6), (2, 4), (3, 5), (4, 8), (5, 13), (8, 24)]
GRID_INTER = [(1, 4, 2), (2, 4, 2), (2, 8, 3), (3, 6, 2), (3, 12, 4),
              (4, 8, 2), (4, 16, 3), (5, 10, 2)]


def all_schedules(s, r, v=1):
    out = [Schedule1F1B(s, r, policy="stash"),
           Schedule1F1B(s, r, policy="vertical"),
           ScheduleGPipe(s, r, weight_versions=1),
           ScheduleGPipe(s, r, weight_versions=2)]
    if r % s == 0:
        out.append(ScheduleInterleaved1F1B(s, r, virtual_stages=v))
        out.append(ScheduleInterleavedAsync1F1B(s, r, virtual_stages=v))
    return out


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_structural_invariants_plain(s, r):
    """validate() checks exactly-once, hop timing, residual liveness."""
    for sched in all_schedules(s, r):
        sched.validate()


@pytest.mark.parametrize("s,r,v", GRID_INTER)
def test_structural_invariants_interleaved(s, r, v):
    ScheduleInterleaved1F1B(s, r, virtual_stages=v).validate()


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_every_microbatch_scheduled_exactly_once(s, r):
    for sched in all_schedules(s, r, v=2):
        tabs = sched.tables()
        want = sorted(range(r)) * sched.virtual_stages
        for stage in range(s):
            f = sorted(m for m in tabs.fwd[:, stage, F_MB] if m >= 0)
            b = sorted(m for m in tabs.bwd[:, stage, B_MB] if m >= 0)
            assert f == sorted(want)
            assert b == sorted(want)


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_forward_before_backward_and_downstream(s, r):
    sched = Schedule1F1B(s, r)
    tabs = sched.tables()
    fwd, bwd = tabs.fwd[:, :, F_MB], tabs.bwd[:, :, B_MB]
    for stage in range(s):
        for m in range(r):
            tf = int(np.where(fwd[:, stage] == m)[0][0])
            tb = int(np.where(bwd[:, stage] == m)[0][0])
            # B(m) at this stage comes at/after the output stage's F(m)
            tf_out = int(np.where(fwd[:, s - 1] == m)[0][0])
            assert tb >= tf_out >= tf
            # activations flow downstream one stage per tick
            if stage + 1 < s:
                tf_next = int(np.where(fwd[:, stage + 1] == m)[0][0])
                assert tf_next == tf + 1
            if stage > 0:
                tb_prev = int(np.where(bwd[:, stage - 1] == m)[0][0])
                assert tb_prev == tb + 1


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_steady_state_no_idle(s, r):
    """Paper: in steady state no GPU is idle — both slots busy."""
    sched = Schedule1F1B(s, r)
    tabs = sched.tables()
    rng = sched.steady_state_ticks()
    if rng is None:
        return
    lo, hi = rng
    for tick in range(lo, hi + 1):
        assert (tabs.fwd[tick, :, F_MB] >= 0).all()
        assert (tabs.bwd[tick, :, B_MB] >= 0).all()


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_max_in_flight_bound(s, r):
    """Microbatches alive between F and B at stage s: ≤ 2(S−1−s)+1 —
    the weight-stash ring size (paper: NOAM versions at the input
    stage)."""
    sched = Schedule1F1B(s, r)
    tabs = sched.tables()
    for stage in range(s):
        live = set()
        peak = 0
        for tick in range(sched.n_ticks):
            if tabs.fwd[tick, stage, F_MB] >= 0:
                live.add(int(tabs.fwd[tick, stage, F_MB]))
            peak = max(peak, len(live))
            if tabs.bwd[tick, stage, B_MB] >= 0:
                live.discard(int(tabs.bwd[tick, stage, B_MB]))
        assert peak <= sched.max_in_flight(stage)
        assert sched.max_in_flight(stage) <= sched.stash_slots


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_stash_ring_slots_never_clobbered(s, r):
    """Ring slot written at F(m) must survive until B(m)."""
    sched = Schedule1F1B(s, r)
    v = sched.stash_slots
    tabs = sched.tables()
    for stage in range(s):
        writer = {}
        for tick in range(sched.n_ticks):
            m = int(tabs.fwd[tick, stage, F_MB])
            if m >= 0:
                slot = m % v
                assert slot not in writer, "slot reused while still live"
                writer[slot] = m
            b = int(tabs.bwd[tick, stage, B_MB])
            if b >= 0:
                assert writer.pop(b % v) == b


@pytest.mark.parametrize("s,r", GRID_PLAIN)
def test_bubble_fraction_matches_simulator(s, r):
    for sched in all_schedules(s, r, v=2):
        sim = simulate_schedule(sched)
        busy = int((sched.tables().fwd[:, :, F_MB] >= 0).sum()
                   + (sched.tables().bwd[:, :, B_MB] >= 0).sum())
        total = 2 * sched.n_ticks * s
        assert abs(sched.bubble_fraction - (1 - busy / total)) < 1e-12
        assert abs(sim.bubble_fraction - sched.bubble_fraction) < 1e-12
        # per-stage slot count: v chunk-F + v chunk-B per microbatch
        assert sim.per_stage_busy == [2 * r * sched.virtual_stages] * s


@pytest.mark.parametrize("s,r,v", GRID_INTER)
def test_interleaving_shrinks_bubble(s, r, v):
    """Bubble strictly below plain 1F1B for v >= 2 (S >= 3; ties at
    S <= 2 where the double-tick startup+drain is already minimal —
    (v−1)(S−2) > 0 is the exact improvement condition)."""
    inter = ScheduleInterleaved1F1B(s, r, virtual_stages=v)
    plain = Schedule1F1B(s, r)
    if v >= 2 and s >= 3:
        assert inter.bubble_fraction < plain.bubble_fraction
    elif s == 2:
        assert inter.bubble_fraction <= plain.bubble_fraction + 1e-12
    # s == 1: interleaving a single stage only adds chunk-chain drain
    if s >= 2:
        # wall-clock: interleaved round never slower per microbatch
        tsim_i = simulate_schedule(inter)
        tsim_p = simulate_schedule(plain)
        assert tsim_i.per_microbatch <= tsim_p.per_microbatch + 1e-12


# ---------------------------------------------------------------------------
# Async interleaved: per-chunk weight-version rings
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,r,v", GRID_INTER)
def test_async_interleaved_shares_interleaved_timing(s, r, v):
    """The async variant changes *versioning*, never timing: (tick,
    stage) microbatch/chunk occupancy, exit/demb tables, bubble and the
    residual ring are identical to flush-interleaved."""
    a = ScheduleInterleavedAsync1F1B(s, r, virtual_stages=v)
    f = ScheduleInterleaved1F1B(s, r, virtual_stages=v)
    ta, tf = a.tables(), f.tables()
    for col in (F_MB, F_CHUNK):
        np.testing.assert_array_equal(ta.fwd[:, :, col], tf.fwd[:, :, col])
    for col in (B_MB, B_CHUNK):
        np.testing.assert_array_equal(ta.bwd[:, :, col], tf.bwd[:, :, col])
    np.testing.assert_array_equal(ta.exit_mb, tf.exit_mb)
    np.testing.assert_array_equal(ta.demb_mb, tf.demb_mb)
    assert a.n_ticks == f.n_ticks
    assert a.bubble_fraction == f.bubble_fraction
    assert a.resid_slots == f.resid_slots
    # ... but the semantics flip: per-microbatch updates over a ring
    assert not a.accumulate and a.uses_stash_ring and not a.fwd_from_stash
    assert f.accumulate and not f.uses_stash_ring


@pytest.mark.parametrize("s,r,v", GRID_INTER + [(2, 4, 1), (4, 8, 1)])
def test_async_per_chunk_ring_never_clobbered(s, r, v):
    """Every chunk's ring slot written at F(m) survives until B(m), and
    slots rotate as m % V per chunk (V = min(2S, R); 2S−1 at v = 1,
    where the timing degenerates to plain 1F1B's)."""
    sched = ScheduleInterleavedAsync1F1B(s, r, virtual_stages=v)
    V = sched.stash_slots
    assert V == max(1, min(2 * s if v > 1 else 2 * s - 1, r))
    tabs = sched.tables()
    for stage in range(s):
        live = {}
        for tick in range(sched.n_ticks):
            fr = tabs.fwd[tick, stage]
            if fr[F_MB] >= 0:
                key = (int(fr[F_CHUNK]), int(fr[F_STASH_WRITE]))
                assert int(fr[F_STASH_WRITE]) == int(fr[F_MB]) % V
                assert key not in live, "slot reused while still live"
                live[key] = int(fr[F_MB])
            br = tabs.bwd[tick, stage]
            if br[B_MB] >= 0:
                key = (int(br[B_CHUNK]), int(br[B_VERSION]))
                assert live.pop(key) == int(br[B_MB])
        assert not live     # every stashed version was read exactly once


def test_registry_and_plan_mapping():
    assert set(SCHEDULES) >= {"1f1b", "gpipe", "interleaved"}
    mk = ParallelismPlan
    assert isinstance(make_schedule(mk(pp=2, tp=1)), Schedule1F1B)
    assert make_schedule(mk(pp=2, tp=1, stash_mode="vertical")).policy \
        == "vertical"
    g = make_schedule(mk(pp=2, tp=1, stash_mode="flush"))
    assert isinstance(g, ScheduleGPipe) and g.stash_slots == 1
    g2 = make_schedule(mk(pp=2, tp=1, stash_mode="2bw"))
    assert g2.stash_slots == 2 and g2.uses_stash_ring
    it = make_schedule(mk(pp=2, tp=1, microbatches=4, stash_mode="flush",
                          schedule="interleaved", virtual_stages=2))
    assert isinstance(it, ScheduleInterleaved1F1B) and it.n_chunks == 4
    ia = make_schedule(mk(pp=2, tp=1, microbatches=4, stash_mode="stash",
                          schedule="interleaved_async", virtual_stages=2))
    assert isinstance(ia, ScheduleInterleavedAsync1F1B)
    assert ia.uses_stash_ring and not ia.accumulate
    assert ia.stash_slots == 4                     # min(2S, R)
    with pytest.raises(AssertionError):            # async needs 'stash'
        make_schedule(mk(pp=2, tp=1, microbatches=4, stash_mode="flush",
                         schedule="interleaved_async", virtual_stages=2))
    # plan-level stash_slots delegates to the schedule
    assert mk(pp=3, tp=1).stash_slots == 5
    assert mk(pp=3, tp=1, stash_mode="flush").stash_slots == 1
    assert mk(pp=3, tp=1, microbatches=12, schedule="interleaved_async",
              virtual_stages=2).stash_slots == 6   # min(2S, R)

    class Custom(Schedule1F1B):
        name = "custom-test"

    register_schedule("custom-test", Custom)
    try:
        assert SCHEDULES["custom-test"] is Custom
    finally:
        del SCHEDULES["custom-test"]


def test_gpipe_residual_ring_full_size():
    """The flush family must keep the full 2(S−1)+1 residual ring even
    with a single weight version — a 1-slot residual ring clobbers the
    input stage's saved activations before its backward reads them
    (seed bug, fixed by separating resid_slots from stash_slots)."""
    g = ScheduleGPipe(4, 8, weight_versions=1)
    assert g.stash_slots == 1
    assert g.resid_slots == 7
    g.validate()   # includes the residual-liveness proof


def test_interleaved_storage_order():
    sch = ScheduleInterleaved1F1B(3, 6, virtual_stages=2)
    order = sch.storage_chunk_order()
    # storage row s*v + j holds chunk j*S + s
    assert list(order) == [0, 3, 1, 4, 2, 5]
    assert sorted(order) == list(range(6))


def test_noam():
    assert paper_noam(8, 7) == 2       # VGG16 "7-1" config
    assert paper_noam(8, 2) == 4
    assert paper_noam(4, 4) == 1       # pure data parallel
    assert paper_noam(16, 9) == 2      # "9-5-1-1"


# ---------------------------------------------------------------------------
# Property-based variants (hypothesis optional)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # grid tests above carry the invariants
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    sizes = st.tuples(st.integers(1, 8), st.integers(1, 24))
    inter_sizes = st.tuples(st.integers(1, 5), st.integers(1, 4),
                            st.integers(1, 4))

    @given(sizes)
    def test_prop_plain_schedules_validate(sr):
        s, r = sr
        for sched in all_schedules(s, r):
            sched.validate()

    @given(inter_sizes)
    def test_prop_interleaved_validates(srv):
        s, groups, v = srv
        sched = ScheduleInterleaved1F1B(s, groups * s, virtual_stages=v)
        sched.validate()
        plain = Schedule1F1B(s, groups * s)
        if v >= 2 and s >= 3:
            assert sched.bubble_fraction < plain.bubble_fraction

    @given(inter_sizes)
    def test_prop_async_ring_rotation(srv):
        """Per-chunk ring invariants over the whole (S, R, v) space:
        validate() proves slot liveness, and each chunk's write sequence
        rotates m % V with no slot revisited inside one ring turn."""
        s, groups, v = srv
        r = groups * s
        sched = ScheduleInterleavedAsync1F1B(s, r, virtual_stages=v)
        sched.validate()    # includes the per-chunk ring liveness proof
        V = sched.stash_slots
        tabs = sched.tables()
        writes = {}         # (stage, chunk) -> [(t, mb, slot)] in t order
        for t in range(sched.n_ticks):
            for stage in range(s):
                fr = tabs.fwd[t, stage]
                if fr[F_MB] >= 0:
                    writes.setdefault((stage, int(fr[F_CHUNK])), []).append(
                        (int(fr[F_MB]), int(fr[F_STASH_WRITE])))
        assert len(writes) == s * v
        for seq in writes.values():
            assert [m for m, _ in seq] == list(range(r))   # m ascending
            assert all(slot == m % V for m, slot in seq)
            for k in range(len(seq) - V):                  # full turn apart
                assert seq[k][1] == seq[k + V][1]
