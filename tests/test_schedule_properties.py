"""Registry-wide schedule property sweep (ISSUE-8 acceptance).

Every schedule in ``core/schedule.py::SCHEDULES`` — training, serving,
and speculative alike — is swept over an (S, v, R, k) space using ONLY
registry-declared traits (``takes_virtual_stages``,
``needs_group_microbatches``, ``is_serving``, ``is_speculative``) to
construct instances: no schedule-specific code, so a newly registered
schedule is covered the moment it registers.

Checked per instance:
  * ``validate()`` passes (each family proves its own invariants);
  * table structure: int32 tables, microbatch ids within range, and
    forward completeness — every (stage, chunk) cell forwards every
    microbatch exactly once;
  * serving only: the bucketed round-trip — ``bucketed(R)`` is the
    identity on the tables, every smaller bucket revalidates with
    exactly ``n_live`` slots and a matching ``live_mask``;
  * speculative only: the accept/rollback contract —
    ``verify_qlen == spec_k + 1``, ``accept_pos_delta`` arithmetic over
    the full 0..spec_k range (typed ValueError outside it), and the
    rollback table mirroring the exit table.

Property-based variants run when hypothesis is installed (it is in
requirements-dev.txt); a fixed-seed random sweep carries the same
checks otherwise.
"""
import numpy as np
import pytest

from repro.core.schedule import F_MB, SCHEDULES

try:
    from hypothesis import given
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _build(cls, s, r, v, k):
    """Instantiate any registered schedule from its declared traits."""
    kw = {}
    if cls.takes_virtual_stages:
        kw["virtual_stages"] = v
    if cls.is_speculative:
        kw["spec_k"] = k
    if (cls.takes_virtual_stages and cls.needs_group_microbatches
            and not cls.is_serving):
        r = max(r - r % s, s)          # full microbatch groups
    return cls(s, r, **kw)


def _check_tables(sched):
    tabs = sched.tables()
    fwd = np.asarray(tabs.fwd)
    S, R = sched.n_stages, sched.n_microbatches
    assert fwd.dtype == np.int32 and fwd.shape[:2] == (sched.n_ticks, S)
    mbs = fwd[:, :, F_MB]
    assert mbs.min() >= -1 and mbs.max() < R
    # forward completeness: every stage forwards every microbatch once
    # per chunk it hosts (v chunks per stage)
    for stage in range(S):
        named = mbs[:, stage]
        counts = np.bincount(named[named >= 0], minlength=R)
        assert (counts == sched.virtual_stages).all(), (
            sched.name, stage, counts)


def _check_bucketed(sched):
    R = sched.n_microbatches
    full = sched.bucketed(R)
    np.testing.assert_array_equal(np.asarray(full.tables().fwd),
                                  np.asarray(sched.tables().fwd))
    assert sched.live_mask().shape == (R,) and sched.live_mask().all()
    for n_live in sorted({1, max(R // 2, 1), R}):
        b = sched.bucketed(n_live)
        b.validate()
        assert b.n_microbatches == n_live
        assert b.live_mask().sum() == n_live
        assert b.n_stages == sched.n_stages
        assert b.virtual_stages == sched.virtual_stages


def _check_speculative(sched):
    k = sched.spec_k
    assert sched.verify_qlen == k + 1
    for a in range(k + 1):
        adv, rolled = sched.accept_pos_delta(a)
        assert (adv, rolled) == (a + 1, k - a)
    for bad in (-1, k + 1):
        with pytest.raises(ValueError, match="accept"):
            sched.accept_pos_delta(bad)
    rb = np.asarray(sched.rollback_table())
    assert rb.shape[0] == sched.n_ticks
    # rollback mirrors the exits: each slot rolls back exactly once
    counts = np.bincount(rb[rb >= 0], minlength=sched.n_microbatches)
    assert (counts == 1).all(), (sched.name, counts)


def check_registry(s, r, v, k):
    """Run the full invariant suite across the whole registry."""
    for name, cls in sorted(SCHEDULES.items()):
        sched = _build(cls, s, r, v, k)
        assert sched.name == name
        sched.validate()
        _check_tables(sched)
        if cls.is_serving:
            _check_bucketed(sched)
        if cls.is_speculative:
            _check_speculative(sched)


GRID = [(1, 1, 1, 1), (2, 2, 1, 1), (2, 4, 2, 3), (3, 6, 2, 2),
        (4, 8, 2, 4), (4, 4, 3, 1)]


@pytest.mark.parametrize("s,r,v,k", GRID)
def test_registry_sweep_grid(s, r, v, k):
    check_registry(s, r, v, k)


def test_registry_covers_all_families():
    """The sweep exercises every declared trait combination present."""
    assert any(c.is_serving for c in SCHEDULES.values())
    assert any(c.is_speculative for c in SCHEDULES.values())
    assert any(c.takes_virtual_stages for c in SCHEDULES.values())
    assert any(not c.is_serving for c in SCHEDULES.values())


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 4), st.integers(1, 12), st.integers(1, 3),
           st.integers(1, 4))
    def test_prop_registry_sweep(s, r, v, k):
        check_registry(s, r, v, k)
else:
    def test_seeded_registry_sweep():
        rng = np.random.default_rng(0)
        for _ in range(40):
            check_registry(int(rng.integers(1, 5)),
                           int(rng.integers(1, 13)),
                           int(rng.integers(1, 4)),
                           int(rng.integers(1, 5)))
