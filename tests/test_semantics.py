"""Paper §3.4 weight-update semantics, validated on a transparent scalar
model, independent of the LM stack.

A hand-rolled 1F1B executor (driven only by Schedule1F1B + a stash ring)
must produce EXACTLY the paper's update rule as implemented by
``staleness_formula_run``:

  stash:     w^(t+1) = w^(t) − ν·∇f(w_1^(t−d_1), …, w_n^(t)),
             d_s = 2(S−1−s) in double-tick units
  vertical:  all stages at delay d_0  ⇒  ≡ delayed BSP

and naive pipelining (no stashing) must differ — the paper's motivation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.reference import staleness_formula_run
from repro.core.schedule import Schedule1F1B
from repro.optim import SGDM


def _scalar_problem(n_stages, seed=0):
    """f(w) = 0.5·(prod_s w_s · x_m − y_m)²; per-stage grads in closed
    form.  Each stage's 'weights' is one scalar."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=64) + 2.0)
    ys = jnp.asarray(rng.normal(size=64) * 0.1 + 1.0)

    def loss_grad_fn(mixed, m):
        # mixed[s]: scalar weight used BY stage s for minibatch m
        def f(ws):
            p = 1.0
            for w in ws:
                p = p * w
            return 0.5 * (p * xs[m] - ys[m]) ** 2

        return list(jax.grad(lambda ws: f(ws))(
            [jnp.asarray(w) for w in mixed]))

    return loss_grad_fn


def _run_1f1b(n_stages, n_mb, loss_grad_fn, opt, mode="stash"):
    """Execute the double-tick 1F1B schedule with a real stash ring.

    F(m) at stage s records the current weights into ring slot m%V and
    *reads* the version it will compute with — latest ('stash') or the
    uniform input-stage version from slot (m−2s)%V ('vertical').  The
    read defines minibatch m's gradient evaluation point component for
    stage s (in the real pipeline it is captured in the activations
    flowing downstream, so ring-slot lifetimes only need to cover each
    stage's OWN reads — tests/test_schedule.py proves they do).  B(m)
    applies the per-stage update with the full gradient at that point.
    Naive mode evaluates at whatever is current when B runs instead.
    """
    sched = Schedule1F1B(n_stages, n_mb)
    v = sched.stash_slots
    w = [jnp.asarray(0.8 + 0.1 * s) for s in range(n_stages)]
    opt_st = [opt.init(w[s]) for s in range(n_stages)]
    stash = [[None] * v for _ in range(n_stages)]
    evalpt = [[None] * n_stages for _ in range(n_mb)]

    for tick in range(sched.n_ticks):
        for s in range(n_stages):
            m = sched.fwd_mb(tick, s)
            if m >= 0:
                stash[s][m % v] = w[s]
                if mode == "vertical":
                    evalpt[m][s] = stash[s][max(m - 2 * s, 0) % v]
                else:
                    evalpt[m][s] = w[s]
        for s in range(n_stages):
            b = sched.bwd_mb(tick, s)
            if b < 0:
                continue
            mixed = list(w) if mode == "naive" else evalpt[b]
            grads = loss_grad_fn(mixed, b)
            w[s], opt_st[s] = opt.update(grads[s], opt_st[s], w[s], b)
    return w


@pytest.mark.parametrize("n_stages,n_mb", [(2, 6), (3, 8), (4, 10)])
def test_stash_matches_staleness_formula(n_stages, n_mb):
    lgf = _scalar_problem(n_stages)
    opt = SGDM(lr=0.02, momentum=0.0)
    got = _run_1f1b(n_stages, n_mb, lgf, opt, mode="stash")
    want, _ = staleness_formula_run(
        None, type("P", (), {"pp": n_stages})(),
        [jnp.asarray(0.8 + 0.1 * s) for s in range(n_stages)],
        lgf, opt, [opt.init(jnp.asarray(0.0))] * n_stages, n_mb,
        mode="stash")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


@pytest.mark.parametrize("n_stages,n_mb", [(2, 6), (3, 9)])
def test_vertical_sync_equals_delayed_bsp(n_stages, n_mb):
    """Vertical sync == BSP with every gradient delayed by d_0 steps
    (paper: 'semantically the same as data parallelism with BSP')."""
    lgf = _scalar_problem(n_stages)
    opt = SGDM(lr=0.02, momentum=0.0)
    got = _run_1f1b(n_stages, n_mb, lgf, opt, mode="vertical")

    # delayed-BSP executor: one weight vector, gradient from version m−d
    d = 2 * (n_stages - 1)
    w = [jnp.asarray(0.8 + 0.1 * s) for s in range(n_stages)]
    hist = [list(w)]
    opt_st = [opt.init(w[s]) for s in range(n_stages)]
    for m in range(n_mb):
        ver = hist[max(m - d, 0)]
        grads = lgf(ver, m)
        for s in range(n_stages):
            w[s], opt_st[s] = opt.update(grads[s], opt_st[s], w[s], m)
        hist.append(list(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(w), rtol=1e-6)


def test_naive_pipelining_diverges_from_valid_gradient():
    """Without stashing, F and B of the same minibatch see different
    weights — the update is not ∇f at any version (paper §3.4)."""
    n_stages, n_mb = 3, 8
    lgf = _scalar_problem(n_stages)
    opt = SGDM(lr=0.05, momentum=0.0)
    stash = _run_1f1b(n_stages, n_mb, lgf, opt, mode="stash")
    naive = _run_1f1b(n_stages, n_mb, lgf, opt, mode="naive")
    assert not np.allclose(np.asarray(stash), np.asarray(naive))


def test_stash_single_stage_equals_sgd():
    """S=1 degenerates to vanilla minibatch SGD."""
    lgf = _scalar_problem(1)
    opt = SGDM(lr=0.05, momentum=0.9)
    got = _run_1f1b(1, 12, lgf, opt, mode="stash")
    w = jnp.asarray(0.8)
    st = opt.init(w)
    for m in range(12):
        g = lgf([w], m)[0]
        w, st = opt.update(g, st, w, m)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(w), rtol=1e-6)
