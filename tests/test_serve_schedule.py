"""Serving schedules as registry clients (ISSUE-4 acceptance).

Covers:
  * structural invariants of the forward-only tables (``validate()``)
    over an (S, R, v) matrix, including partial microbatch groups and
    the R = 1 sequence-parallel decode case;
  * serve_ttft closed forms and the simulator cross-check —
    ``serve_interleaved`` TTFT < ``serve_1f`` TTFT at S >= 3;
  * the KV/SSM cache term of the serving memory_model (golden values,
    dp/tp/sp sharding);
  * ``plan_search(workload="decode")`` rejecting a plan whose
    KV-cache-inclusive memory_model exceeds the HBM budget (golden);
  * the ``fit_decode_microbatches`` regression — a clear ValueError
    (not ZeroDivisionError) when dp does not divide the batch;
  * the registry-lookup error path of ``make_serving_schedule`` and the
    train -> serve storage-order round trip.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import profiler as prof
from repro.core.partitioner import plan_search
from repro.core.schedule import (SCHEDULES, ScheduleInterleaved1F1B,
                                 ScheduleServe1F, ScheduleServeInterleaved,
                                 default_cache_lens, make_serving_schedule,
                                 serve_ttft, serving_cache_bytes,
                                 weighted_round_time)
from repro.models import spec as S
from repro.parallel.mesh import ParallelismPlan

HW = dataclasses.replace(prof.TPU_V5E, hbm_bytes=1e18)


def mk_spec(n_layers=8, heads=4, d_model=256, d_ff=1024, vocab=1024,
            n_kv=None):
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense")
                   for _ in range(n_layers))
    return S.ModelSpec(name="t", d_model=d_model, n_layers=n_layers,
                       n_heads=heads, n_kv=n_kv or heads,
                       d_head=max(d_model // heads, 8), d_ff=d_ff,
                       vocab=vocab, blocks=blocks, norm="rmsnorm",
                       act="silu")


# ---------------------------------------------------------------------------
# table invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2, 3, 4])
@pytest.mark.parametrize("r", [1, 2, 3, 5, 8])
def test_serve_1f_tables_valid(s, r):
    sched = ScheduleServe1F(s, r)
    sched.validate()
    assert sched.n_ticks == r + s - 1
    # the fwd timing is the classic 1F pipe: stage s forwards t - s
    tabs = sched.tables()
    for t in range(sched.n_ticks):
        for st in range(s):
            f = t - st
            assert tabs.fwd[t, st, 0] == (f if 0 <= f < r else -1)


@pytest.mark.parametrize("s", [1, 2, 3, 4])
@pytest.mark.parametrize("r", [1, 2, 3, 5, 8])
@pytest.mark.parametrize("v", [2, 3])
def test_serve_interleaved_tables_valid(s, r, v):
    """Any R is valid — no microbatch-group constraint forward-only."""
    sched = ScheduleServeInterleaved(s, r, virtual_stages=v)
    sched.validate()
    if r % s == 0:              # full groups: closed-form tick count
        assert sched.n_ticks == v * r + s - 1


def test_serve_interleaved_storage_order_matches_training():
    """The serving chunk-major layout IS the training one — what lets
    reshard_state_for_plan round-trip train -> serve checkpoints."""
    for s, v in [(2, 2), (4, 2), (2, 4), (3, 3)]:
        train = ScheduleInterleaved1F1B(s, s, virtual_stages=v)
        serve = ScheduleServeInterleaved(s, 1, virtual_stages=v)
        np.testing.assert_array_equal(train.storage_chunk_order(),
                                      serve.storage_chunk_order())


def test_serving_schedules_registered():
    assert SCHEDULES["serve_1f"] is ScheduleServe1F
    assert SCHEDULES["serve_interleaved"] is ScheduleServeInterleaved
    assert ScheduleServe1F.is_serving
    assert not SCHEDULES["1f1b"].is_serving


def test_make_serving_schedule_resolution_and_error():
    # training plans map onto the serving analogue of their chunking
    plan = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    assert make_serving_schedule(plan).name == "serve_1f"
    iplan = ParallelismPlan(pp=4, tp=1, microbatches=8, stash_mode="flush",
                            schedule="interleaved", virtual_stages=2)
    sched = make_serving_schedule(iplan, 6)
    assert sched.name == "serve_interleaved"
    assert sched.virtual_stages == 2 and sched.n_microbatches == 6
    # registry-lookup error path (replaces the old virtual_stages == 1
    # assert): a serve resolution missing from the registry raises
    saved = SCHEDULES.pop("serve_interleaved")
    try:
        with pytest.raises(KeyError, match="registered serving schedules"):
            make_serving_schedule(iplan, 6)
    finally:
        SCHEDULES["serve_interleaved"] = saved
    # an unknown/typo'd name errors too — never a silent serve_1f
    typo = plan.with_(schedule="serve_interlaved")
    with pytest.raises(KeyError, match="serve_interlaved"):
        make_serving_schedule(typo, 4)


# ---------------------------------------------------------------------------
# TTFT + simulator cross-check
# ---------------------------------------------------------------------------

def test_serve_ttft_closed_forms():
    for s in (2, 3, 4):
        r = 2 * s
        assert serve_ttft(ScheduleServe1F(s, r)) == pytest.approx(
            r + s - 1)
        for v in (2, 4):
            got = serve_ttft(ScheduleServeInterleaved(s, r,
                                                      virtual_stages=v))
            assert got == pytest.approx((v * r + s - 1) / v)


@pytest.mark.parametrize("s", [3, 4, 6])
def test_interleaved_serving_cuts_ttft_at_depth(s):
    """Acceptance: serve_interleaved TTFT < serve_1f TTFT at S >= 3,
    cross-checked against the table-walking simulator."""
    from benchmarks.simulator import simulate_schedule
    r = 2 * s
    one = ScheduleServe1F(s, r)
    two = ScheduleServeInterleaved(s, r, virtual_stages=2)
    assert serve_ttft(two) < serve_ttft(one)
    # the simulator walks the same forward-only tables: its round_time
    # equals the TTFT (the prefill round IS the ramp), both measures
    sim1, sim2 = simulate_schedule(one), simulate_schedule(two)
    assert sim1.round_time == pytest.approx(serve_ttft(one))
    assert sim2.round_time == pytest.approx(serve_ttft(two))
    assert sim2.round_time < sim1.round_time
    # weighted_round_time agrees (no backward slots to charge)
    assert weighted_round_time(two)[0] == pytest.approx(serve_ttft(two))


def test_partial_groups_never_slower_than_1f():
    for s in (2, 3, 4):
        for r in (1, 3, 5, 7):
            for v in (2, 3):
                assert serve_ttft(ScheduleServeInterleaved(
                    s, r, virtual_stages=v)) <= serve_ttft(
                        ScheduleServe1F(s, r)) + 1e-12


# ---------------------------------------------------------------------------
# KV/SSM cache memory model
# ---------------------------------------------------------------------------

def test_serving_cache_bytes_golden():
    """2 (K,V) × rows × len × kv_heads × d_head × 2 B per attn layer,
    rows sharded over dp, heads over tp, positions over dp under sp."""
    spec = mk_spec(n_layers=8, heads=4, d_model=256)
    plan = ParallelismPlan(pp=4, tp=1, decode_microbatches=8)
    sched = make_serving_schedule(plan)
    dp, gb, cl = 4, 128, 32768
    got = serving_cache_bytes(spec, plan, sched, cache_len=cl,
                              global_batch=gb, data_replicas=dp)
    # 2 layers/stage, rows = 128/4 = 32 per device
    want = 2 * 2.0 * (gb / dp) * cl * spec.n_kv * spec.d_head * 2.0
    assert got == pytest.approx(want)
    # tp shards the KV heads
    tplan = ParallelismPlan(pp=2, tp=2, decode_microbatches=8)
    tsched = make_serving_schedule(tplan)
    gt = serving_cache_bytes(spec, tplan, tsched, cache_len=cl,
                             global_batch=gb, data_replicas=dp)
    want_t = 4 * 2.0 * (gb / dp) * cl * (spec.n_kv // 2) * spec.d_head * 2.0
    assert gt == pytest.approx(want_t)
    # sp: rows replicate, full-length positions shard — same total here
    gsp = serving_cache_bytes(spec, plan, sched, cache_len=cl,
                              global_batch=gb // dp, sp=True,
                              data_replicas=dp)
    want_sp = 2 * 2.0 * (gb / dp) * (cl / dp) * spec.n_kv \
        * spec.d_head * 2.0
    assert gsp == pytest.approx(want_sp)


def test_serving_memory_model_fields():
    spec = mk_spec()
    plan = ParallelismPlan(pp=4, tp=1, decode_microbatches=8)
    sched = make_serving_schedule(plan)
    mm = sched.memory_model(spec, plan, HW, microbatch_tokens=16,
                            data_replicas=4, cache_len=4096,
                            global_batch=128)
    assert mm.cache_bytes > 0
    assert mm.stash_bytes == mm.grad_bytes == mm.optimizer_bytes == 0.0
    assert mm.resid_bytes == 0.0
    assert mm.total_bytes == pytest.approx(
        mm.weight_bytes + mm.workspace_bytes + mm.cache_bytes)
    assert "cache" in str(mm)
    with pytest.raises(AssertionError, match="cache_len"):
        sched.memory_model(spec, plan, HW, microbatch_tokens=16)
    # prefill forces full-length caches on windowed stacks
    blocks = tuple(S.BlockSpec(mixer="attn", ffn="dense", window=64)
                   for _ in range(8))
    wspec = dataclasses.replace(spec, blocks=blocks)
    assert default_cache_lens(wspec, 4, 4096) == [64, 64]
    dec = sched.memory_model(wspec, plan, HW, microbatch_tokens=16,
                             data_replicas=4, cache_len=4096,
                             global_batch=128)
    pre = sched.memory_model(wspec, plan, HW, microbatch_tokens=16,
                             data_replicas=4, cache_len=4096,
                             global_batch=128, prefill=True)
    assert pre.cache_bytes > dec.cache_bytes   # ring buffers vs slabs


# ---------------------------------------------------------------------------
# plan_search workload axis
# ---------------------------------------------------------------------------

def test_plan_search_decode_rejects_kv_over_budget():
    """Acceptance golden: a decode plan whose KV-cache-inclusive
    memory_model exceeds Hardware.hbm_bytes is rejected."""
    spec = mk_spec(n_layers=8, heads=4, d_model=256)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    kw = dict(minibatch_tokens=32, data_replicas=1,
              workload="decode", cache_len=131072, global_batch=256)
    cands = plan_search(spec, base, 4, HW, return_all=True, **kw)
    assert cands and all(c.workload == "decode" for c in cands)
    assert all(c.memory.cache_bytes > 0 for c in cands)
    for c in cands:
        assert c.plan.make_schedule().is_serving
    # every candidate's KV cache alone blows a 1 GB budget -> no plan
    assert min(c.memory.cache_bytes for c in cands) > 1e9
    with pytest.raises(AssertionError, match="no plan fits"):
        plan_search(spec, base, 4, HW, hbm_bytes=1e9, **kw)
    # a budget between cache-inclusive and cache-free totals rejects the
    # over-budget candidates but keeps the lean ones
    totals = sorted(c.memory.total_bytes for c in cands)
    if totals[0] < totals[-1]:
        budget = (totals[0] + totals[-1]) / 2
        best = plan_search(spec, base, 4, HW, hbm_bytes=budget, **kw)
        assert best.feasible and best.memory.total_bytes <= budget


def test_plan_search_prefill_prefers_interleaved_at_depth():
    """The TTFT objective picks serve_interleaved over serve_1f when the
    pipe is deep (heads=3 pins tp=1 -> pp=4 is the only split)."""
    spec = mk_spec(n_layers=8, heads=3, d_model=192)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    kw = dict(minibatch_tokens=512, data_replicas=1, workload="prefill",
              cache_len=512, global_batch=8)
    cands = plan_search(spec, base, 4, HW, return_all=True, **kw)
    assert all(c.plan.pp == 4 for c in cands)
    best = cands[0]
    assert best.plan.schedule == "serve_interleaved"
    one = [c for c in cands if c.plan.schedule == "serve_1f"]
    assert one and best.round_time < min(c.round_time for c in one)
    best.plan.make_schedule().validate()


def test_plan_search_prices_the_fitted_microbatch_count():
    """The planner must score the R the engine will actually run: the
    batch-fitted count (global_batch / dp caps it) and R = 1 under
    sequence-parallel decode — not the config's nominal R."""
    spec = mk_spec()
    base = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    # dp=4 over batch 8 leaves 2 rows per replica -> R = 2, not 8
    best = plan_search(spec, base, 4, HW, minibatch_tokens=2,
                       data_replicas=4, workload="decode", cache_len=1024,
                       global_batch=8)
    assert best.plan.make_schedule().n_microbatches == 2
    # sp decode replicates rows: R = 1 regardless of the config
    sp_best = plan_search(spec, base, 4, HW, minibatch_tokens=1,
                          data_replicas=4, workload="decode",
                          cache_len=1024, global_batch=1, sp=True)
    assert sp_best.plan.make_schedule().n_microbatches == 1
    # an indivisible batch fails with the engine's own clear error
    with pytest.raises(ValueError, match="not divisible"):
        plan_search(spec, base, 4, HW, minibatch_tokens=1,
                    data_replicas=3, workload="decode", cache_len=1024,
                    global_batch=8)


def test_plan_search_serving_rejects_training_schedules():
    spec = mk_spec()
    base = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    with pytest.raises(AssertionError, match="does not run"):
        plan_search(spec, base, 4, HW, minibatch_tokens=32,
                    workload="decode", cache_len=1024, global_batch=8,
                    schedules=("1f1b",))
    with pytest.raises(AssertionError, match="cache_len"):
        plan_search(spec, base, 4, HW, minibatch_tokens=32,
                    workload="decode")


# ---------------------------------------------------------------------------
# train -> serve checkpoint round trip
# ---------------------------------------------------------------------------

def test_reshard_train_to_serve_roundtrip():
    """The serving engine stores weights in the training chunk-major
    order, so a train checkpoint at (pp, v) is IDENTICAL under a serve
    plan at (pp, v); a cross-layout move regroups parameters without
    inventing stash/optimizer state for a serving tree."""
    from repro.models.spec import stage_varying_scalars
    from repro.runtime.driver import reshard_state_for_plan
    spec = mk_spec(n_layers=8)
    train = ParallelismPlan(pp=2, tp=1, microbatches=4, stash_mode="flush",
                            schedule="interleaved", virtual_stages=2)
    serve = ParallelismPlan(pp=2, tp=1, decode_microbatches=4,
                            schedule="serve_interleaved", virtual_stages=2)
    rng = np.random.default_rng(0)
    stages = {"layer_0": {"w": rng.standard_normal((4, 3, 3))}}
    cache = {"layer_0": {"kv": rng.standard_normal((4, 2, 5))}}
    w, t = stage_varying_scalars(spec, 4)
    state = {"params": {"stages": stages,
                        "layer_windows": np.asarray(w),
                        "layer_thetas": np.asarray(t)},
             "cache": cache, "pos": 0}
    out = reshard_state_for_plan(state, spec, train, serve)
    assert out is state          # same chunk-major layout: identity
    # cross-layout: (pp=2, v=2) serve -> (pp=4, v=1) serve regroups the
    # interleaved storage rows [0, 2, 1, 3] back to layer-major — the
    # cache rows ride the SAME permutation as the weights
    serve1 = ParallelismPlan(pp=4, tp=1, decode_microbatches=4,
                             schedule="serve_1f")
    out2 = reshard_state_for_plan(state, spec, serve, serve1)
    assert "stash" not in out2 and "opt_stages" not in out2
    order = ScheduleServeInterleaved(2, 4,
                                     virtual_stages=2).storage_chunk_order()
    np.testing.assert_allclose(
        np.asarray(out2["params"]["stages"]["layer_0"]["w"]),
        stages["layer_0"]["w"][np.argsort(order)])
    np.testing.assert_allclose(
        np.asarray(out2["cache"]["layer_0"]["kv"]),
        cache["layer_0"]["kv"][np.argsort(order)])
    # across chunk counts the per-row layer groups change: a live cache
    # cannot be re-cut — refuse loudly instead of silently misaligning
    serve_half = ParallelismPlan(pp=2, tp=1, decode_microbatches=4,
                                 schedule="serve_1f")
    with pytest.raises(ValueError, match="re-prefill"):
        reshard_state_for_plan(state, spec, serve, serve_half)


def test_reshard_partially_filled_serving_state():
    """ISSUE-5 satellite: a continuous-batching serving state — cache
    rows filled only for live slots, per-slot ``pos``/``live`` arrays —
    reshards across storage layouts with the slot-major arrays riding
    along unchanged (they index slots, not chunks: the chunk-row
    permutation must move cache rows while leaving them aligned), and
    still refuses across chunk counts."""
    from repro.models.spec import stage_varying_scalars
    from repro.runtime.driver import reshard_state_for_plan
    spec = mk_spec(n_layers=8)
    serve = ParallelismPlan(pp=2, tp=1, decode_microbatches=4,
                            schedule="serve_interleaved", virtual_stages=2)
    rng = np.random.default_rng(3)
    R = 4
    # chunk-major cache [4 storage rows, R slots, ...]: slots 1 and 3
    # live (partially filled rows), slots 0 and 2 freed (zeros)
    live = np.asarray([0, 1, 0, 1], np.int32)
    pos = np.asarray([0, 7, 0, 3], np.int32)
    kv = rng.standard_normal((4, R, 2, 5)) * live[None, :, None, None]
    w, t = stage_varying_scalars(spec, 4)
    state = {"params": {"stages": {"layer_0":
                                   {"w": rng.standard_normal((4, 3, 3))}},
                        "layer_windows": np.asarray(w),
                        "layer_thetas": np.asarray(t)},
             "cache": {"layer_0": {"kv": kv}}, "pos": pos, "live": live}
    serve1 = ParallelismPlan(pp=4, tp=1, decode_microbatches=4,
                             schedule="serve_1f")
    out = reshard_state_for_plan(state, spec, serve, serve1)
    order = ScheduleServeInterleaved(2, R,
                                     virtual_stages=2).storage_chunk_order()
    # cache rows permuted chunk-major -> layer-major; the slot axis (and
    # with it which slots are filled) is untouched
    np.testing.assert_array_equal(np.asarray(out["cache"]["layer_0"]["kv"]),
                                  kv[np.argsort(order)])
    np.testing.assert_array_equal(np.asarray(out["pos"]), pos)
    np.testing.assert_array_equal(np.asarray(out["live"]), live)
    # freed slots stay all-zero in every storage row after the permute
    assert (np.asarray(out["cache"]["layer_0"]["kv"])[:, live == 0]
            == 0).all()
    # across chunk counts: refuse, exactly as before
    half = ParallelismPlan(pp=2, tp=1, decode_microbatches=4,
                           schedule="serve_1f")
    with pytest.raises(ValueError, match="re-prefill"):
        reshard_state_for_plan(state, spec, serve, half)


# ---------------------------------------------------------------------------
# slot-liveness masks (continuous batching) + occupancy pricing
# ---------------------------------------------------------------------------

def test_masked_serve_tables_valid():
    """with_live_slots blanks dead slots into bubbles; validate() proves
    the forward-only contract over the live slots only."""
    for s, r, v in [(1, 1, 1), (2, 4, 1), (2, 4, 2), (4, 8, 2), (3, 5, 3)]:
        sched = (ScheduleServe1F(s, r) if v == 1
                 else ScheduleServeInterleaved(s, r, virtual_stages=v))
        for live in (None, range(r), [0], [r - 1],
                     range(0, r, 2)):
            m = sched.with_live_slots(live)
            m.validate()
            n_live = r if live is None else len(list(live))
            assert m.live_count == n_live
            tabs = m.tables()
            assert int((tabs.exit_mb >= 0).sum()) == n_live
            fwd_mbs = tabs.fwd[:, :, 0]
            assert set(fwd_mbs[fwd_mbs >= 0].tolist()) == (
                set(range(r)) if live is None else set(live))
    # live timing is unchanged by masking: the live slots' rows match
    full = ScheduleServeInterleaved(2, 4, virtual_stages=2)
    masked = full.with_live_slots([1, 3])
    ft, mt = full.tables(), masked.tables()
    keep = np.isin(ft.fwd[:, :, 0], [1, 3])
    np.testing.assert_array_equal(ft.fwd[keep], mt.fwd[keep])
    assert (mt.fwd[~keep, 0] == -1).all()
    # out-of-range / duplicate masks are rejected
    with pytest.raises(AssertionError, match="out of range"):
        full.with_live_slots([7])


def test_masked_round_time_shrinks_with_occupancy():
    """Drained ticks cost nothing: the weighted round of a half-live
    batch is strictly cheaper than the full batch, never cheaper than
    a single slot."""
    for sched in (ScheduleServe1F(2, 8),
                  ScheduleServeInterleaved(4, 8, virtual_stages=2)):
        full, _ = weighted_round_time(sched)
        half, _ = weighted_round_time(sched.with_live_slots(range(4)))
        one, _ = weighted_round_time(sched.with_live_slots([0]))
        assert one < half < full


def test_plan_search_occupancy_prices_masked_tables():
    """ISSUE-5: decode plan_search can price expected occupancy instead
    of assuming full R — the score shrinks with occupancy while the
    memory budget keeps charging the full-R capacity."""
    spec = mk_spec(n_layers=8, heads=4, d_model=256)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    kw = dict(minibatch_tokens=32, data_replicas=1, workload="decode",
              cache_len=4096, global_batch=8)
    full = plan_search(spec, base, 4, HW, return_all=True, **kw)
    half = plan_search(spec, base, 4, HW, return_all=True,
                       occupancy=0.5, **kw)
    by_plan = {(c.plan.pp, c.plan.schedule, c.plan.virtual_stages): c
               for c in full}
    assert all(c.occupancy == 0.5 for c in half)
    for c in half:
        f = by_plan[(c.plan.pp, c.plan.schedule, c.plan.virtual_stages)]
        assert c.round_time < f.round_time          # drained ticks free
        assert c.memory.total_bytes == f.memory.total_bytes  # capacity
    # occupancy is a decode-only knob
    with pytest.raises(AssertionError, match="occupancy"):
        plan_search(spec, base, 4, HW, minibatch_tokens=32,
                    data_replicas=1, workload="prefill", cache_len=4096,
                    global_batch=8, occupancy=0.5)
    with pytest.raises(AssertionError):
        plan_search(spec, base, 4, HW, occupancy=0.0, **kw)


def test_bucketed_tables_are_truncated_masked_tables():
    """ISSUE-7: ``bucketed(k)`` is the full-R table with the dead-slot
    tail *deleted*, not masked — the bucket's tables are exactly the
    live prefix of ``with_live_slots(range(k))`` and the truncated tail
    held only bubbles (re-proving the validate() argument externally:
    a slot's timing depends on its own index, never on R)."""
    for s, r, v in [(2, 4, 1), (2, 8, 1), (4, 8, 2), (3, 5, 1)]:
        sched = (ScheduleServe1F(s, r) if v == 1
                 else ScheduleServeInterleaved(s, r, virtual_stages=v))
        for k in (1, 2, r - 1, r):
            if k < 1:
                continue
            b = sched.bucketed(k)
            b.validate()
            assert b.n_microbatches == k and b.live_slots is None
            masked = sched.with_live_slots(range(k))
            bt, mt = b.tables(), masked.tables()
            np.testing.assert_array_equal(bt.fwd, mt.fwd[:b.n_ticks])
            np.testing.assert_array_equal(bt.exit_mb,
                                          mt.exit_mb[:b.n_ticks])
            assert (mt.fwd[b.n_ticks:, :, 0] < 0).all()
            # the planner's masked price == the executor's bucket price
            assert weighted_round_time(b) == weighted_round_time(masked)
    with pytest.raises(ValueError, match="outside"):
        ScheduleServe1F(2, 4).bucketed(0)
    with pytest.raises(ValueError, match="outside"):
        ScheduleServe1F(2, 4).bucketed(5)


def test_bucket_lattice_and_pick():
    from repro.core.schedule import bucket_lattice, pick_bucket
    assert bucket_lattice(1) == (1,)
    assert bucket_lattice(6) == (1, 2, 4, 6)
    assert bucket_lattice(8) == (1, 2, 4, 8)
    assert bucket_lattice(16) == (1, 2, 4, 8, 16)
    with pytest.raises(ValueError):
        bucket_lattice(0)
    lat = bucket_lattice(8)
    assert pick_bucket(0, lat) == 1     # empty batch still runs a program
    assert pick_bucket(1, lat) == 1
    assert pick_bucket(3, lat) == 4
    assert pick_bucket(8, lat) == 8
    with pytest.raises(ValueError, match="fits"):
        pick_bucket(9, lat)


def test_plan_search_occupancy_prices_bucket_lattice():
    """ISSUE-7: occupancy pricing quantizes to the executor's bucket
    lattice — the scored round is the one the liveness-aware engine
    actually runs, and the chosen bucket rides along on PlanChoice."""
    from repro.core.schedule import bucket_lattice, pick_bucket
    spec = mk_spec(n_layers=8, heads=4, d_model=256)
    base = ParallelismPlan(pp=4, tp=1, microbatches=8,
                           decode_microbatches=8)
    kw = dict(minibatch_tokens=32, data_replicas=1, workload="decode",
              cache_len=4096, global_batch=8)
    full = plan_search(spec, base, 4, HW, return_all=True, **kw)
    assert all(c.bucket is None for c in full)     # full R: no variant
    import math
    from repro.core.schedule import fit_serving_microbatches
    r = fit_serving_microbatches(base.decode_microbatches, 8, 1)
    for occ in (0.2, 0.5):
        cands = plan_search(spec, base, 4, HW, return_all=True,
                            occupancy=occ, **kw)
        want = pick_bucket(max(1, math.ceil(occ * r)), bucket_lattice(r))
        for c in cands:
            assert c.bucket == want, (c.plan, c.bucket, want)

def test_fit_decode_microbatches_validates_dp():
    from repro.serving.engine import fit_decode_microbatches
    plan = ParallelismPlan(pp=2, tp=1, decode_microbatches=8)
    assert fit_decode_microbatches(plan, 16, 2) == 8
    assert fit_decode_microbatches(plan, 12, 2) == 6
    assert fit_decode_microbatches(plan, 2, 2) == 1
    # dp does not divide the batch: a clear error naming batch and dp —
    # the old loop walked R to 0 and died with ZeroDivisionError
    with pytest.raises(ValueError, match="global_batch=4.*dp=3"):
        fit_decode_microbatches(plan, 4, 3)
    with pytest.raises(ValueError, match="not divisible"):
        fit_decode_microbatches(plan, 7, 2)
    # a degenerate microbatch count is a clear error, not ZeroDivision
    from repro.core.schedule import fit_serving_microbatches
    with pytest.raises(ValueError, match="decode_microbatches=0"):
        fit_serving_microbatches(0, 8, 2)
