"""Pipelined serving correctness: prefill+decode greedy continuation must
equal teacher-forced full forward passes (single device, pp=1 exercises
the full engine code path: pipelined scan, KV/SSM/WKV state rings,
windowed ring-buffer caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_host_mesh
from repro.models import lm_head
from repro.models.init import init_params
from repro.models.stage import full_transformer, make_statics
from repro.parallel.mesh import ParallelismPlan, split_model_axis
from repro.serving.engine import build_serving, default_cache_lens

ARCHS = ["qwen3_14b", "gemma3_4b", "h2o_danube3_4b", "rwkv6_1b6",
         "jamba_v01_52b", "olmoe_1b_7b"]


def _greedy_teacher(spec, params, tokens, n_new, plan):
    """Full (non-incremental) forward over the growing sequence."""
    statics = make_statics(spec, plan, tokens_per_mb=tokens.shape[1] + n_new)
    seq = tokens
    outs = []
    for _ in range(n_new + 1):
        emb = lm_head.embed_tokens(params["embed"], seq)
        pos = jnp.broadcast_to(jnp.arange(seq.shape[1]), seq.shape)
        h, _ = full_transformer(params, emb.astype(jnp.float32), statics,
                                positions=pos)
        nxt = lm_head.sample_greedy(
            params["head"], params["final_norm"]["scale"], h[:, -1:],
            norm_kind=spec.norm, norm_bias=params["final_norm"].get("bias"),
            vocab=spec.vocab)
        outs.append(np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
    return np.stack(outs)          # (n_new+1, B)


# >60s cases carry the slow marker (fast set keeps one per family)
SLOW_SERVE = {"jamba_v01_52b", "qwen3_14b", "rwkv6_1b6"}


@pytest.mark.parametrize(
    "arch", [a if a not in SLOW_SERVE
             else pytest.param(a, marks=pytest.mark.slow) for a in ARCHS])
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = configs.get(arch)
    spec = cfg.smoke_spec()
    if spec.encoder is not None or spec.frontend == "vision":
        pytest.skip("text-only teacher")
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1,
                           decode_microbatches=1)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    batch, prefill, n_new, cache = 2, 12, 5, 32
    sb = build_serving(spec, plan, dmesh, cache_len=cache,
                       global_batch=batch, prefill_len=prefill,
                       compute_dtype=jnp.float32)
    state = sb.init_state(jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (1, batch, prefill), 1,
                                spec.vocab, jnp.int32)

    state, nxt = jax.jit(sb.prefill_step)(state, {"tokens": tokens})
    got = [np.asarray(nxt)]
    dec = jax.jit(sb.decode_step)
    for _ in range(n_new):
        state, nxt = dec(state, nxt)
        got.append(np.asarray(nxt))
    got = np.stack(got)

    want = _greedy_teacher(spec, state["params"], tokens[0], n_new, plan)
    np.testing.assert_array_equal(got, want)


@pytest.mark.slow
def test_windowed_ring_cache_matches_full_cache():
    """SWA decode with a window-sized ring buffer == full-length cache."""
    cfg = configs.get("h2o_danube3_4b")
    spec = cfg.smoke_spec()           # window=8 layers
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1,
                           decode_microbatches=1)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    lens = default_cache_lens(spec, 1, 64)
    assert all(l == 8 for l in lens)  # ring buffers, not full length

    outs = {}
    for cache in (64,):
        sb = build_serving(spec, plan, dmesh, cache_len=cache,
                           global_batch=2, prefill_len=10,
                           compute_dtype=jnp.float32)
        state = sb.init_state(jax.random.key(0))
        tokens = jax.random.randint(jax.random.key(1), (1, 2, 10), 1,
                                    spec.vocab, jnp.int32)
        state, nxt = jax.jit(sb.prefill_step)(state, {"tokens": tokens})
        seq = [np.asarray(nxt)]
        dec = jax.jit(sb.decode_step)
        for _ in range(16):           # run well past the window
            state, nxt = dec(state, nxt)
            seq.append(np.asarray(nxt))
        outs[cache] = np.stack(seq)
    want = _greedy_teacher(spec, state["params"],
                           tokens[0], 16, plan)
    np.testing.assert_array_equal(outs[64], want)


def test_start_reentry_after_donated_decode_is_bit_exact():
    """ISSUE-5 satellite: ``start(); decode(); start(); decode()``.

    ``EngineSession.decode`` jits with ``donate_argnums=0`` — the state
    buffers of every decode are donated.  Re-calling ``start()`` must
    rebuild a fresh state (never alias donated buffers), so replaying
    the same session from the same key reproduces the first run
    bit-exactly, prefill included."""
    cfg = configs.get("olmoe_1b_7b")
    spec = cfg.smoke_spec()
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1,
                           decode_microbatches=1)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    sb = build_serving(spec, plan, dmesh, cache_len=32, global_batch=2,
                       prefill_len=8, compute_dtype=jnp.float32)
    tokens = jax.random.randint(jax.random.key(1), (1, 2, 8), 1,
                                spec.vocab, jnp.int32)

    def one_run():
        sb.start(jax.random.key(0))
        toks = [np.asarray(sb.prefill({"tokens": tokens}))]
        for _ in range(4):
            toks.append(np.asarray(sb.decode(jnp.asarray(toks[-1]))))
        return np.stack(toks)

    first = one_run()
    second = one_run()               # same session object, same _jit cache
    np.testing.assert_array_equal(first, second)
    # and the state the replay left behind is live (not donated junk)
    third = np.asarray(sb.decode(jnp.asarray(second[-1])))
    assert third.shape == (2,)


def test_prefill_without_prefill_len_raises_value_error():
    """ISSUE-5 satellite: the decode-only guard survives ``python -O``
    and names the fix (prefill_len=)."""
    cfg = configs.get("olmoe_1b_7b")
    spec = cfg.smoke_spec()
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1,
                           decode_microbatches=1)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    sb = build_serving(spec, plan, dmesh, cache_len=32, global_batch=2,
                       prefill_len=0, compute_dtype=jnp.float32)
    assert sb.prefill_step is None and sb.admit_step is None
    with pytest.raises(ValueError, match="prefill_len"):
        sb.prefill({"tokens": jnp.ones((1, 2, 8), jnp.int32)})
    with pytest.raises(ValueError, match="prefill_len"):
        sb.write_prefill_into_slots({"tokens": jnp.ones((1, 2, 8),
                                                        jnp.int32)},
                                    np.ones((1,), np.int32))


def test_whisper_enc_dec_serving_runs():
    cfg = configs.get("whisper_medium")
    spec = cfg.smoke_spec()
    plan = ParallelismPlan(pp=1, tp=1, microbatches=1,
                           decode_microbatches=1)
    mesh = make_host_mesh(data=1, model=1)
    dmesh = split_model_axis(mesh, 1, 1)
    sb = build_serving(spec, plan, dmesh, cache_len=32, global_batch=2,
                       prefill_len=8, compute_dtype=jnp.float32)
    state = sb.init_state(jax.random.key(0))
    e = spec.encoder
    batch = {
        "tokens": jnp.ones((1, 2, 8), jnp.int32),
        "frames": 0.02 * jax.random.normal(
            jax.random.key(1), (1, 2, e.source_len, e.d_model)),
    }
    state, nxt = jax.jit(sb.prefill_step)(state, batch)
    for _ in range(4):
        state, nxt = jax.jit(sb.decode_step)(state, nxt)
    assert np.asarray(nxt).shape == (2,)
    assert (np.asarray(nxt) >= 0).all()
