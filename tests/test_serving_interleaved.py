"""Interleaved serving == 1F serving, bit-level (fp32) — ISSUE-4.

Each case runs tests/serve_check.py in a subprocess so it can set
--xla_force_host_platform_device_count before jax initializes (the main
pytest process keeps 1 device per the task spec).  The worker builds
the SAME model under ``serve_1f`` and ``serve_interleaved`` and asserts
identical greedy continuations (prefill + decode); at dp = tp = 1 the
reference is additionally pinned to the non-incremental teacher.

A fast case runs by default; the full matrix — S ∈ {2, 4}, v = 2, TP,
and sequence-parallel decode — carries the ``slow`` marker.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

# data, pp, tp, v, sp, steps
FAST_MATRIX = [
    (1, 2, 1, 2, 0, 4),     # S=2, v=2, prefill + decode, teacher-pinned
]

SLOW_MATRIX = [
    (1, 4, 1, 2, 0, 4),     # S=4 deep pipe, teacher-pinned
    (1, 2, 2, 2, 0, 4),     # tensor parallelism (GQA KV sharded)
    (2, 2, 1, 2, 1, 4),     # sequence-parallel decode (R=1, sharded KV)
]


def _run_case(case):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, os.path.join(HERE, "serve_check.py"),
         *[str(a) for a in case]],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nERR:\n{out.stderr}"
    assert "MATCH" in out.stdout


@pytest.mark.parametrize("case", FAST_MATRIX,
                         ids=lambda c: "d{}xpp{}xtp{}v{}{}".format(
                             *c[:4], "_sp" if c[4] else ""))
def test_serve_interleaved_matches_1f(case):
    _run_case(case)


@pytest.mark.slow
@pytest.mark.parametrize("case", SLOW_MATRIX,
                         ids=lambda c: "d{}xpp{}xtp{}v{}{}".format(
                             *c[:4], "_sp" if c[4] else ""))
def test_serve_interleaved_matches_1f_full(case):
    _run_case(case)
