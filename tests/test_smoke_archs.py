"""Per-arch smoke tests: reduced same-family config, one pipelined train
round on CPU (single device, sequential reference executor — identical
semantics to the SPMD pipeline, see tests/test_pipeline_spmd.py).

Asserts: finite loss, all parameters updated, shapes preserved, no NaNs.
The FULL configs are exercised only via the dry-run (task spec).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.reference import reference_init_state, reference_train_step
from repro.optim import SGDM


def _batch(spec, plan, key, seq_len=24, bmb=2):
    r = plan.microbatches
    n_patch = spec.n_patches if spec.frontend == "vision" else 0
    text = seq_len - n_patch
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (r, bmb, text), 0, spec.vocab,
                                     jnp.int32),
        "labels": jax.random.randint(ks[1], (r, bmb, text), 0, spec.vocab,
                                     jnp.int32),
    }
    if spec.frontend == "vision":
        batch["patches"] = 0.02 * jax.random.normal(
            ks[2], (r, bmb, n_patch, spec.d_model), jnp.float32)
    if spec.encoder is not None:
        e = spec.encoder
        batch["frames"] = 0.02 * jax.random.normal(
            ks[3], (r, bmb, e.source_len, e.d_model), jnp.float32)
    return batch



# Fast tier-1 representatives (one per major family); the rest carry the
# ``slow`` marker and run via `pytest -m slow` / scripts/tier1.sh --full.
FAST_ARCHS = ("qwen3_14b", "olmoe_1b_7b", "rwkv6_1b6")


def _arch_params():
    return [arch if arch in FAST_ARCHS
            else pytest.param(arch, marks=pytest.mark.slow)
            for arch in configs.ARCH_IDS]


@pytest.mark.parametrize("arch", _arch_params())
def test_smoke_train_round(arch):
    cfg = configs.get(arch)
    spec, plan = cfg.smoke_spec(), cfg.SMOKE_PLAN
    opt = SGDM(lr=0.01, momentum=0.9)
    state = reference_init_state(spec, plan, opt, jax.random.key(0))
    batch = _batch(spec, plan, jax.random.key(1))

    new_state, metrics = reference_train_step(spec, plan, state, batch, opt)

    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, loss
    # every parameter leaf finite and shape-stable; most visibly updated
    # (leaves behind doubly-down-scaled init paths get ~1e-8..1e-13
    # gradients that underflow an fp32 0.5/1.0 init after one SGD step —
    # gradient LIVENESS is asserted exactly in test_gradient_liveness)
    old_flat = jax.tree_util.tree_leaves_with_path(state["params"])
    new_flat = jax.tree_util.tree_leaves_with_path(new_state["params"])
    n_changed = 0
    for (pa, old), (pb, new) in zip(old_flat, new_flat):
        assert pa == pb and new.shape == old.shape, (pa, pb)
        assert np.isfinite(np.asarray(new, np.float32)).all(), pa
        if not np.array_equal(np.asarray(new), np.asarray(old)):
            n_changed += 1
    assert n_changed >= 0.6 * len(old_flat), (arch, n_changed,
                                              len(old_flat))


@pytest.mark.parametrize("arch", _arch_params())
def test_gradient_liveness(arch):
    """No dead parameters: every stage leaf gets a nonzero gradient."""
    import jax.numpy as jnp
    from repro.models.init import init_params
    from repro.models.stage import full_transformer, make_statics

    cfg = configs.get(arch)
    spec = cfg.smoke_spec()
    plan = cfg.SMOKE_PLAN.with_(tp=1, pp=2)
    params, _ = init_params(spec, plan, jax.random.key(0), jnp.float32)
    st = make_statics(spec, plan, tokens_per_mb=48)
    x = 0.1 * jax.random.normal(jax.random.key(1), (2, 24, spec.d_model))
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    cross = (0.02 * jax.random.normal(
        jax.random.key(2),
        (2, spec.encoder.source_len, spec.encoder.d_model))
        if spec.encoder is not None else None)

    def loss(stages):
        p2 = dict(params)
        p2["stages"] = stages
        h, aux = full_transformer(p2, x, st, cross_x=cross, positions=pos)
        return (h.astype(jnp.float32) ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(params["stages"])
    dead = [jax.tree_util.keystr(p)
            for p, leaf in jax.tree_util.tree_leaves_with_path(g)
            if float(jnp.abs(leaf).max()) == 0.0]
    assert not dead, (arch, dead)


@pytest.mark.parametrize("arch", _arch_params())
def test_smoke_second_round_consumes_state(arch):
    """Round 2 runs off round 1's state (stash ring layout survives)."""
    cfg = configs.get(arch)
    spec, plan = cfg.smoke_spec(), cfg.SMOKE_PLAN
    opt = SGDM(lr=0.01, momentum=0.9)
    state = reference_init_state(spec, plan, opt, jax.random.key(0))
    b1 = _batch(spec, plan, jax.random.key(1))
    b2 = _batch(spec, plan, jax.random.key(2))
    state, m1 = reference_train_step(spec, plan, state, b1, opt)
    state, m2 = reference_train_step(spec, plan, state, b2, opt)
    assert int(state["step"]) == 2
    assert np.isfinite(float(m2["loss"]))
